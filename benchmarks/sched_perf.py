"""Simulation-engine performance study: reference vs indexed vs compiled.

Four parts, all emitted into ``BENCH_sched_perf.json``:

  * **equivalence gate** — pinned scenarios across scheduling policies x
    intra disciplines x arbiter policies x topologies, each simulated by
    both engines; every ``SimResult`` field (makespan, per-dim wire bytes /
    busy time / service logs / op order, per-request finish times) must be
    **bit-identical**.  Any mismatch raises, failing the benchmark (and CI).
    The gate runs with ``check_invariants=True``, so the runtime invariant
    sanitizer (``repro.core.invariants``) audits every scenario too.  Every
    policy x discipline scenario additionally runs ``engine="compiled"``
    (the cohort-vectorized fast path) and must match the indexed result
    bit-for-bit.
  * **headline** — the 256-request x 64-chunk ``simulate_requests`` stream
    (quick mode: 64 x 16).  All three engines are timed on identical
    inputs with ``stage_ops_per_sec`` recorded per engine; the full run
    asserts the indexed engine is >= 20x faster than reference with equal
    results.
  * **scaling** — stage-op sweeps across policies / topologies / arbiters;
    a log-log least-squares fit of indexed-engine wall time vs total
    stage-ops must give an exponent <= 1.2 (quick mode only backstops at
    1.6 — its sub-100ms points are too noisy on shared CI runners for a
    tight wall-clock gate).
  * **compiled tier** — deep-backlog AR streams (4096-chunk collectives,
    ``fusion_limit=1024``, prebuilt ``TaskArrays``) out to ~10.5M
    stage-ops.  The full run gates the cohort engine's contract: >= 10x
    indexed throughput at >= 1M stage-ops, a fitted compiled scaling
    exponent <= 1.05 out to 10M, bit-identity at every size indexed is
    run at, and the 10M point finishing in single-digit seconds.  Timing
    is warmup-then-interleaved min-of-k (first calls populate the
    per-TaskArrays caches; the minimum is the noise-robust estimator on
    shared runners).  Quick mode runs a ~131k-524k-op subset with loose
    backstop thresholds.

Run standalone (``python -m benchmarks.sched_perf [--quick]``) or via
``python -m benchmarks.run sched_perf`` (full mode; regenerates the
committed JSON, including the slow reference-engine headline timing).
"""
from __future__ import annotations

import json
import math
import sys
from pathlib import Path

from benchmarks.common import row, timed_best
from repro.core.requests import CollectiveRequest
from repro.core.simulator import simulate_requests
from repro.tenancy import FabricArbiter, TenantSpec, simulate_fabric, synthetic_requests
from repro.topology import make_table2_topologies

MB = 1e6
OUT_JSON = Path(__file__).resolve().parents[1] / "BENCH_sched_perf.json"


def _assert_equal(res_a, res_b, label: str) -> None:
    bad = res_a.diff_fields(res_b)
    if bad:
        raise AssertionError(
            f"engine equivalence violated on {label}: fields {bad} differ "
            f"between engines")


def _ar_stream(n_req: int, n_chunk: int, size_mb: float = 20.0):
    reqs = [CollectiveRequest("AR", size_mb * MB, issue_time=i * 1e-4)
            for i in range(n_req)]
    return reqs, n_chunk


def _stage_ops(groups) -> int:
    return sum(len(c.schedule) for grp in groups for c in grp)


# ---------------------------------------------------------------------------
# Equivalence gate
# ---------------------------------------------------------------------------
def equivalence_gate(topos, quick: bool) -> list[str]:
    checked: list[str] = []
    topo_names = ("2D-SW_SW", "3D-SW_SW_SW_hetero")
    policies = ("baseline", "themis") if quick else (
        "baseline", "themis", "themis_indep_ag", "lookahead", "themis_guarded")

    for tname in topo_names:
        topo = topos[tname]
        # policies x disciplines (single-job engine)
        for policy in policies:
            for intra in ("SCF", "FIFO"):
                reqs = [CollectiveRequest(["AR", "RS", "AG"][i % 3],
                                          (4 + 9 * (i % 4)) * MB,
                                          issue_time=i * 1.3e-4,
                                          priority=i % 2)
                        for i in range(18)]
                ri, _ = simulate_requests(topo, reqs, policy=policy,
                                          chunks_per_collective=8,
                                          intra=intra, engine="indexed",
                                          check_invariants=True)
                rr, _ = simulate_requests(topo, reqs, policy=policy,
                                          chunks_per_collective=8,
                                          intra=intra, engine="reference",
                                          check_invariants=True)
                # compiled leg: no check_invariants (a fast-path blocker
                # by design — the sanitizer hooks the scalar loops), so
                # this is a genuine cohort-engine run, held to the same
                # bit-identity bar against the sanitized indexed result.
                rc, _ = simulate_requests(topo, reqs, policy=policy,
                                          chunks_per_collective=8,
                                          intra=intra, engine="compiled")
                label = f"{tname}/{policy}/{intra}"
                _assert_equal(ri, rr, label)
                _assert_equal(ri, rc, label + "/compiled")
                checked.append(label)
        # arbiter policies (multi-tenant engine, incl. preemption)
        specs = [TenantSpec("heavy", weight=1.0),
                 TenantSpec("light", weight=1.0, priority=1,
                            slo_slowdown=1.5)]
        reqs = (synthetic_requests("heavy", "AR", 200 * MB, 2)
                + synthetic_requests("light", "AR", 8 * MB, 6,
                                     gap_s=0.0004, start_s=0.0002))
        for arb_policy in ("fifo", "strict-priority", "weighted-fair",
                           "slo-aware"):
            out = {}
            for eng in ("indexed", "reference"):
                arb = FabricArbiter(arb_policy, specs,
                                    isolated_latency={"light": 0.001})
                out[eng], _ = simulate_fabric(topo, reqs, arbiter=arb,
                                              chunks_per_collective=8,
                                              engine=eng,
                                              check_invariants=True)
            label = f"{tname}/arbiter:{arb_policy}"
            _assert_equal(out["indexed"], out["reference"], label)
            checked.append(label)
    return checked


# ---------------------------------------------------------------------------
# Headline: 256 x 64 request stream
# ---------------------------------------------------------------------------
def headline(topos, quick: bool) -> dict:
    n_req, n_chunk = (64, 16) if quick else (256, 64)
    topo = topos["3D-SW_SW_SW_homo"]
    reqs, chunks = _ar_stream(n_req, n_chunk)
    (res_idx, groups), t_idx = timed_best(
        simulate_requests, topo, reqs, chunks_per_collective=chunks,
        engine="indexed")
    (res_cmp, _), t_cmp = timed_best(
        simulate_requests, topo, reqs, chunks_per_collective=chunks,
        engine="compiled", repeat=2)
    (res_ref, _), t_ref = timed_best(
        simulate_requests, topo, reqs, chunks_per_collective=chunks,
        engine="reference")
    _assert_equal(res_idx, res_ref, f"headline {n_req}x{n_chunk}")
    _assert_equal(res_idx, res_cmp, f"headline {n_req}x{n_chunk}/compiled")
    speedup = t_ref / t_idx
    ops = _stage_ops(groups)
    out = {
        "n_requests": n_req,
        "chunks_per_collective": chunks,
        "stage_ops": ops,
        "indexed_s": t_idx,
        "compiled_s": t_cmp,
        "reference_s": t_ref,
        "speedup": speedup,
        "compiled_speedup_vs_indexed": t_idx / t_cmp,
        "stage_ops_per_sec": {
            "indexed": ops / t_idx,
            "compiled": ops / t_cmp,
            "reference": ops / t_ref,
        },
        "makespan_s": res_idx.makespan,
        "bit_equivalent": True,
    }
    if not quick and speedup < 20.0:
        raise AssertionError(
            f"headline speedup {speedup:.1f}x < 20x on {n_req}x{n_chunk}")
    return out


# ---------------------------------------------------------------------------
# Scaling sweeps
# ---------------------------------------------------------------------------
def _fit_exponent(points: list[tuple[int, float]]) -> float:
    """Least-squares slope of log(time) vs log(stage_ops)."""
    xs = [math.log(p[0]) for p in points]
    ys = [math.log(p[1]) for p in points]
    n = len(xs)
    mx, my = sum(xs) / n, sum(ys) / n
    num = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
    den = sum((x - mx) ** 2 for x in xs)
    return num / den


def scaling(topos, quick: bool) -> dict:
    sizes = ((16, 8), (32, 16), (64, 32)) if quick else (
        (32, 8), (64, 16), (128, 32), (256, 64))
    combos = [
        ("themis/3D-SW_SW_SW_homo", "themis", "3D-SW_SW_SW_homo", None),
        ("baseline/2D-SW_SW", "baseline", "2D-SW_SW", None),
        ("themis/2D-SW_SW/weighted-fair", "themis", "2D-SW_SW",
         "weighted-fair"),
    ]
    out: dict = {"sizes": [f"{r}x{c}" for r, c in sizes], "combos": {}}
    for label, policy, tname, arb_policy in combos:
        topo = topos[tname]
        pts = []
        rows_detail = []
        for n_req, n_chunk in sizes:
            reqs, chunks = _ar_stream(n_req, n_chunk)
            arbiter = None
            if arb_policy is not None:
                # two alternating tenants so the arbiter actually arbitrates
                reqs = [CollectiveRequest(r.collective, r.size_bytes,
                                          issue_time=r.issue_time,
                                          tenant=f"t{i % 2}")
                        for i, r in enumerate(reqs)]
                arbiter = FabricArbiter(arb_policy,
                                        [TenantSpec("t0"), TenantSpec("t1")])
            repeat = 3 if (n_req * n_chunk) <= 1024 else 1
            (res, groups), secs = timed_best(
                simulate_requests, topo, reqs, policy=policy,
                chunks_per_collective=chunks, arbiter=arbiter,
                engine="indexed", repeat=repeat)
            ops = _stage_ops(groups)
            pts.append((ops, secs))
            rows_detail.append({"n_requests": n_req, "chunks": n_chunk,
                                "stage_ops": ops, "indexed_s": secs})
        exp = _fit_exponent(pts)
        out["combos"][label] = {"points": rows_detail, "exponent": exp}
    main_exp = out["combos"]["themis/3D-SW_SW_SW_homo"]["exponent"]
    out["exponent"] = main_exp
    # The full run is the authoritative <= 1.2 gate.  Quick mode fits three
    # sub-100ms points on a possibly loaded CI runner, so its threshold is
    # only a loose backstop against gross (superquadratic-class) regressions
    # — the hard quick-mode gate is the bit-equivalence check above.
    limit = 1.6 if quick else 1.2
    if main_exp > limit:
        raise AssertionError(
            f"fitted scaling exponent {main_exp:.3f} > {limit}")
    return out


# ---------------------------------------------------------------------------
# Compiled tier: cohort-engine throughput out to ~10.5M stage-ops
# ---------------------------------------------------------------------------
def compiled_tier(topos, quick: bool) -> dict:
    """Deep-backlog AR streams through the cohort-vectorized engine.

    The stream shape is the fast path's home turf and the indexed heap's
    worst case at once: one 4096-chunk 20MB themis AR per request, issued
    every 100us (a deep standing backlog), ``fusion_limit=1024`` so
    cohorts stay large, and a prebuilt ``TaskArrays`` replayed into every
    run.  Full-mode sizes reach ~10.5M stage-ops; indexed is timed at the
    two smaller sizes only (it is ~15x slower at the mid size — timing it
    at 10M would dominate the whole benchmark for no extra information).

    Timing: one untimed warmup call per (engine, size) — the first call
    pays fingerprint validation plus the per-TaskArrays column/class
    caches — then ``reps`` interleaved passes keeping the per-size
    minimum, which is the noise-robust estimator on 1-core shared
    runners.  Bit-identity is asserted at every size indexed runs at.
    """
    from repro.core.latency_model import LatencyModel
    from repro.core.scheduler import schedule_collective
    from repro.core.simulator import build_task_arrays, simulate
    import time

    topo = topos["2D-SW_SW"]
    n_chunk = 4096
    sizes = (8, 16, 32) if quick else (64, 208, 640)
    reps = 2 if quick else 4
    idx_sizes = sizes[:2]
    g = schedule_collective(topo, "AR", 20 * MB, n_chunk, "themis")
    lm = LatencyModel.for_topology(topo)
    cases = {}
    for n_req in sizes:
        groups = [g] * n_req
        issue = [i * 1e-4 for i in range(n_req)]
        prios = [0] * n_req
        ta = build_task_arrays(lm, groups, prios, ["default"] * n_req)
        cases[n_req] = (groups, issue, prios, ta)

    def run_once(n_req, engine):
        groups, issue, prios, ta = cases[n_req]
        return simulate(topo, groups, engine=engine, issue_times=issue,
                        priorities=prios, fusion_limit=1024, task_arrays=ta)

    best_c = {n: float("inf") for n in sizes}
    best_i = {n: float("inf") for n in idx_sizes}
    identical = {}
    for n_req in sizes:
        rc = run_once(n_req, "compiled")  # warmup + identity reference
        if n_req in best_i:
            ri = run_once(n_req, "indexed")
            bad = ri.diff_fields(rc)
            if bad:
                raise AssertionError(
                    f"compiled tier: fields {bad} differ from indexed at "
                    f"{n_req} requests")
            identical[n_req] = True
        rc = ri = None
    for _ in range(reps):
        for n_req in sizes:
            t0 = time.perf_counter()
            r = run_once(n_req, "compiled")
            best_c[n_req] = min(best_c[n_req], time.perf_counter() - t0)
            r = None
    for _ in range(min(reps, 2)):
        for n_req in idx_sizes:
            t0 = time.perf_counter()
            r = run_once(n_req, "indexed")
            best_i[n_req] = min(best_i[n_req], time.perf_counter() - t0)
            r = None

    points = []
    for n_req in sizes:
        ops = cases[n_req][3].n_tasks
        tc = best_c[n_req]
        pt = {
            "n_requests": n_req,
            "stage_ops": ops,
            "compiled_s": tc,
            "stage_ops_per_sec": ops / tc,
            "bit_equivalent": identical.get(n_req),
        }
        if n_req in best_i:
            pt["indexed_s"] = best_i[n_req]
            pt["speedup_vs_indexed"] = best_i[n_req] / tc
        points.append(pt)
    exp = _fit_exponent([(p["stage_ops"], p["compiled_s"]) for p in points])
    # the >=1M-stage-op speedup gate reads the biggest indexed-timed point
    gate_pt = next(p for p in reversed(points) if "indexed_s" in p)
    out = {
        "topology": "2D-SW_SW",
        "chunks_per_collective": n_chunk,
        "fusion_limit": 1024,
        "points": points,
        "exponent": exp,
        "speedup_at_gate_point": gate_pt["speedup_vs_indexed"],
        "gate_point_stage_ops": gate_pt["stage_ops"],
    }
    if quick:
        # loose backstops: sub-second points on shared CI runners
        if gate_pt["speedup_vs_indexed"] < 2.0:
            raise AssertionError(
                f"compiled tier (quick): speedup "
                f"{gate_pt['speedup_vs_indexed']:.1f}x < 2x backstop")
        if exp > 1.6:
            raise AssertionError(
                f"compiled tier (quick): exponent {exp:.3f} > 1.6 backstop")
    else:
        if gate_pt["speedup_vs_indexed"] < 10.0:
            raise AssertionError(
                f"compiled tier: speedup {gate_pt['speedup_vs_indexed']:.1f}x "
                f"< 10x at {gate_pt['stage_ops']} stage-ops")
        if exp > 1.05:
            raise AssertionError(
                f"compiled tier: fitted exponent {exp:.3f} > 1.05")
        big = points[-1]
        if big["compiled_s"] >= 10.0:
            raise AssertionError(
                f"compiled tier: {big['stage_ops']} stage-ops took "
                f"{big['compiled_s']:.1f}s (want single-digit seconds)")
    return out


def run(quick: bool = False):
    topos = make_table2_topologies()
    report: dict = {"mode": "quick" if quick else "full"}
    rows = []

    checked = equivalence_gate(topos, quick)
    report["equivalence"] = {"scenarios": checked, "ok": True}
    rows.append(row("sched_perf/equivalence", 0.0,
                    f"{len(checked)} scenarios bit-identical"))

    hl = headline(topos, quick)
    report["headline"] = hl
    rows.append(row(
        f"sched_perf/headline/{hl['n_requests']}x{hl['chunks_per_collective']}",
        hl["indexed_s"] * 1e6,
        f"speedup={hl['speedup']:.1f}x ref={hl['reference_s']:.3f}s "
        f"idx={hl['indexed_s']:.3f}s stage_ops={hl['stage_ops']}"))

    sc = scaling(topos, quick)
    report["scaling"] = sc
    for label, combo in sc["combos"].items():
        biggest = combo["points"][-1]
        rows.append(row(
            f"sched_perf/scaling/{label}", biggest["indexed_s"] * 1e6,
            f"exponent={combo['exponent']:.3f} "
            f"largest={biggest['stage_ops']} stage-ops"))

    ct = compiled_tier(topos, quick)
    report["compiled_tier"] = ct
    big = ct["points"][-1]
    rows.append(row(
        "sched_perf/compiled_tier", big["compiled_s"] * 1e6,
        f"exponent={ct['exponent']:.3f} "
        f"speedup={ct['speedup_at_gate_point']:.1f}x@"
        f"{ct['gate_point_stage_ops']} "
        f"largest={big['stage_ops']} stage-ops "
        f"({big['stage_ops_per_sec'] / 1e6:.2f}M/s)"))

    OUT_JSON.write_text(json.dumps(report, indent=2) + "\n")
    rows.append(row("sched_perf/json", 0.0, f"json={OUT_JSON.name}"))
    return rows


def main() -> None:
    quick = "--quick" in sys.argv[1:]
    print("name,us_per_call,derived")
    for name, us, derived in run(quick=quick):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
