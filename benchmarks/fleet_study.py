"""Serving-fleet overload study: open-loop load past saturation, with
and without admission control, plus the SLO-debt elastic-weight payoff.

Four parts, emitted into ``BENCH_fleet.json``:

  * **calibrate** — the observe→actuate loop: a closed back-to-back batch
    measures the fabric's saturation service rate; a *traced* run at that
    rate feeds ``repro.fleet.calibrate_admission`` (peak windowed queue
    depth → admission capacity, makespan/requests → the deadline policy's
    service-time estimate).
  * **knee** — offered load swept through and past saturation
    (0.5–1.75x) under an open-loop Poisson process, once with no
    admission (the baseline that queues unboundedly) and once per
    admission policy.  Metrics per point: goodput (live finished
    requests / makespan), p99 *request* latency (arrival → last decode
    token), and shed rate.  Gates: at >=1.5x the admission path keeps
    p99 within 3x its at-capacity value while the baseline p99 keeps
    growing, and admission goodput stays within 10% of the at-capacity
    maximum.
  * **differential** — every overload scenario (each policy, plus
    overload composed with a mid-run dim outage from ``repro.faults``)
    runs through BOTH engines with the runtime invariant sanitizer
    armed; any field diff fails the study.
  * **slo_debt** — two-tenant bursty overload on three Table-2
    topologies: :class:`repro.tenancy.SloDebtArbiter` (debt-integrating
    boost with hysteresis) vs the instantaneous ``slo-aware`` policy,
    scored on the worst tenant's SLO-violation rate; the gate demands
    the debted controller is no worse on >= 2 of 3 topologies.

Run standalone (``python -m benchmarks.fleet_study [--quick]``) or via
``python -m benchmarks.run fleet``.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from benchmarks.common import row, timed
from repro.faults import DimOutage, FaultSchedule, RetryPolicy
from repro.fleet import (
    AdmissionController,
    FleetTenant,
    MMPPArrivals,
    PoissonArrivals,
    calibrate_admission,
    fleet_tenant_specs,
    fleet_traffic,
    unit_of_group,
)
from repro.obs import BwTimeline, Tracer
from repro.tenancy import FabricArbiter, SloDebtArbiter
from repro.topology import make_table2_topologies
from repro.traffic.builders import serving_traffic
from repro.traffic.engine import simulate_traffic

OUT_JSON = Path(__file__).resolve().parents[1] / "BENCH_fleet.json"

# Acceptance gates (see ISSUE/ROADMAP): p99 containment factor under
# admission at overload, and the goodput retention vs the at-capacity max.
P99_GATE = 3.0
GOODPUT_GATE = 0.9

# One serving request's cost model for the study (heavy enough that the
# 2D fabric saturates at a few hundred requests/s).
COSTS = dict(prefill_bytes=512e6, decode_bytes=24e6,
             prefill_s=1e-3, decode_s=1e-4, prefill_ops=2, gen_tokens=6)


def _topo():
    return make_table2_topologies()["2D-SW_SW"]


def _unit_metrics(res, unit_of):
    """Per-unit (request) arrival / finish / liveness.

    Arrival is the unit's gate issue time (static, open-loop); finish is
    the max live group finish.  Shed or failed units are dead.
    """
    dead_groups = {g for g, _ in res.shed_groups}
    dead_groups.update(g for g, _ in res.failed_groups)
    n_units = max(unit_of) + 1 if unit_of else 0
    arrive = [float("inf")] * n_units
    finish = [0.0] * n_units
    tenant = [""] * n_units
    alive = [True] * n_units
    for g, u in enumerate(unit_of):
        arrive[u] = min(arrive[u], res.group_issue[g])
        tenant[u] = res.group_tenants[g]
        if g in dead_groups:
            alive[u] = False
        else:
            finish[u] = max(finish[u], res.group_finish[g])
    return [(tenant[u], arrive[u], finish[u], alive[u])
            for u in range(n_units)]


def _p99(vals):
    if not vals:
        return 0.0
    s = sorted(vals)
    return s[min(len(s) - 1, int(0.99 * len(s)))]


# -- part 1: calibration (observe -> actuate) --------------------------------

def calibrate_part(quick: bool) -> tuple[dict, list]:
    topo = _topo()
    n = 12 if quick else 24
    # Closed batch: all requests arrive at t=0; saturation service rate
    # is what the fabric actually drains.
    g = serving_traffic(name="cal", arrival_times=[0.0] * n, **COSTS)
    (res, _), us = timed(simulate_traffic, topo, g, engine="indexed")
    sat_rate = n / res.makespan

    # Traced run *at* capacity: open-loop Poisson at the measured rate.
    ten = [FleetTenant("web", PoissonArrivals(sat_rate, seed=7),
                       serving=dict(COSTS))]
    horizon = (8 if quick else 16) / sat_rate
    gat = fleet_traffic(ten, horizon_s=horizon)
    trc = Tracer()
    simulate_traffic(topo, gat, engine="indexed", tracer=trc)
    n_req = sum(1 for node in gat.nodes
                if node.name.endswith("prefill-compute"))
    # 64 chunks per collective x (prefill burst + decode chain) wire
    # collectives per request converts chunk-stage depth to units.
    cpu = 64.0 * (COSTS["prefill_ops"] + COSTS["gen_tokens"])
    calib = calibrate_admission(BwTimeline.from_tracer(trc),
                                window_s=res.makespan / n,
                                n_requests=n_req, target_depth=3.0,
                                chunks_per_unit=cpu)
    out = {"sat_rate_rps": sat_rate, "closed_makespan_s": res.makespan,
           **calib}
    rows = [row("fleet/calibrate", us,
                f"sat={sat_rate:.0f}rps capacity={calib['capacity']} "
                f"est_service={calib['est_service_s']:.2e}s "
                f"peak_depth={calib['peak_depth']:.1f}")]
    return out, rows


# -- part 2: the knee --------------------------------------------------------

def _overload_run(topo, rate, horizon, *, admission=None, seed=11,
                  engine="indexed", faults=None, check=True):
    ten = [FleetTenant("web", PoissonArrivals(rate, seed=seed),
                       serving=dict(COSTS))]
    g = fleet_traffic(ten, horizon_s=horizon)
    uo, up = unit_of_group(g)
    adm = None
    if admission is not None:
        adm = AdmissionController(uo, unit_priority=up, **admission)
    res, _ = simulate_traffic(topo, g, engine=engine, admission=adm,
                              faults=faults, check_invariants=check)
    return res, uo


def knee_part(quick: bool, calib: dict) -> tuple[dict, list]:
    topo = _topo()
    sat = calib["sat_rate_rps"]
    cap = int(calib["capacity"])
    est = calib["est_service_s"]
    loads = (0.75, 1.0, 1.5) if quick else (0.5, 0.75, 1.0, 1.25, 1.5, 1.75)
    horizon = (10 if quick else 24) / sat
    policies = {
        "reject-newest": dict(policy="reject-newest", capacity=cap),
        "shed-lowest-priority": dict(policy="shed-lowest-priority",
                                     capacity=cap),
        "deadline-aware": dict(policy="deadline-aware", capacity=cap,
                               deadline_s=cap * est, est_service_s=est),
    }
    points = []
    rows = []
    for x in loads:
        rate = x * sat
        pt = {"load_x": x, "rate_rps": rate}
        res, uo = _overload_run(topo, rate, horizon)
        units = _unit_metrics(res, uo)
        lats = [f - a for _, a, f, alive in units if alive]
        pt["baseline"] = {
            "p99_s": _p99(lats),
            "goodput_rps": len(lats) / res.makespan,
            "shed_rate": 0.0, "n_requests": len(units),
        }
        for name, kw in policies.items():
            res, uo = _overload_run(topo, rate, horizon, admission=kw)
            units = _unit_metrics(res, uo)
            lats = [f - a for _, a, f, alive in units if alive]
            n_shed = sum(1 for u in units if not u[3])
            pt[name] = {
                "p99_s": _p99(lats),
                "goodput_rps": len(lats) / res.makespan,
                "shed_rate": n_shed / len(units) if units else 0.0,
            }
        points.append(pt)
        rows.append(row(
            f"fleet/knee/load={x}x", 0.0,
            f"base_p99={pt['baseline']['p99_s']:.2e}s "
            f"adm_p99={pt['reject-newest']['p99_s']:.2e}s "
            f"shed={pt['reject-newest']['shed_rate']:.0%} "
            f"goodput={pt['reject-newest']['goodput_rps']:.0f}rps"))

    at_cap = next(p for p in points
                  if abs(p["load_x"] - 1.0) < 1e-9)
    over = [p for p in points if p["load_x"] >= 1.5]
    gates = {}
    # Gate 1: admission p99 containment at overload.
    gates["p99_bounded"] = all(
        p[name]["p99_s"] <= P99_GATE * max(at_cap[name]["p99_s"], 1e-12)
        for p in over for name in policies)
    # Gate 2: the no-admission baseline keeps growing past saturation.
    gates["baseline_p99_grows"] = all(
        p["baseline"]["p99_s"] > at_cap["baseline"]["p99_s"]
        for p in over)
    # Gate 3: goodput retention under shedding.
    best = max(p["reject-newest"]["goodput_rps"] for p in points)
    gates["goodput_retained"] = all(
        p["reject-newest"]["goodput_rps"] >= GOODPUT_GATE * best
        for p in over)
    if not all(gates.values()):
        raise AssertionError(f"fleet knee gates failed: {gates} "
                             f"(points={points})")
    out = {"loads": list(loads), "horizon_s": horizon, "points": points,
           "gates": gates}
    rows.append(row("fleet/knee_gates", 0.0,
                    f"p99<= {P99_GATE}x goodput>={GOODPUT_GATE:.0%} "
                    f"baseline-unbounded: all passed"))
    return out, rows


# -- part 3: differential engine equivalence under overload ------------------

def differential_part(quick: bool, calib: dict) -> tuple[dict, list]:
    topo = _topo()
    sat = calib["sat_rate_rps"]
    cap = int(calib["capacity"])
    est = calib["est_service_s"]
    horizon = (8 if quick else 16) / sat
    outage = FaultSchedule(
        events=(DimOutage(dim=1, start=0.3 * horizon,
                          end=0.45 * horizon),),
        retry=RetryPolicy(timeout_s=0.1 * horizon,
                          backoff_s=0.02 * horizon, max_attempts=4))
    scenarios = [
        ("reject-newest", dict(policy="reject-newest", capacity=cap), None),
        ("shed-lowest-priority",
         dict(policy="shed-lowest-priority", capacity=cap), None),
        ("deadline-aware",
         dict(policy="deadline-aware", capacity=cap,
              deadline_s=cap * est, est_service_s=est), None),
        ("overload+outage", dict(policy="reject-newest", capacity=cap),
         outage),
    ]
    results = []
    n_shed = 0
    for name, kw, faults in scenarios:
        res_i, _ = _overload_run(topo, 1.6 * sat, horizon, admission=kw,
                                 engine="indexed", faults=faults)
        res_r, _ = _overload_run(topo, 1.6 * sat, horizon, admission=kw,
                                 engine="reference", faults=faults)
        diff = res_i.diff_fields(res_r)
        if diff:
            raise AssertionError(
                f"engines diverged under overload ({name}): {diff}")
        n_shed += len(res_i.shed_groups)
        results.append({"scenario": name,
                        "shed_groups": len(res_i.shed_groups),
                        "failed_groups": len(res_i.failed_groups),
                        "identical": True})
    if n_shed == 0:
        raise AssertionError("differential scenarios shed nothing — the "
                             "overload never engaged the controller")
    out = {"scenarios": results, "all_identical": True,
           "total_shed_groups": n_shed}
    rows = [row("fleet/differential", 0.0,
                f"scenarios={len(scenarios)} identical=all "
                f"shed_groups={n_shed} sanitizer=armed")]
    return out, rows


# -- part 4: SLO-debt vs instantaneous slo-aware -----------------------------

def _slo_tenants(sat: float):
    """A steady web tenant with a tight SLO against a bursty batch tenant
    that periodically swamps the fabric — the flapping regime where an
    instantaneous boost oscillates and a debted one holds."""
    period = 4.0 / sat
    return [
        FleetTenant("web", PoissonArrivals(0.45 * sat, seed=3),
                    serving=dict(COSTS), weight=1.0, slo_slowdown=2.5),
        FleetTenant("batch",
                    MMPPArrivals((0.1 * sat, 1.4 * sat),
                                 (period, period), seed=4),
                    serving=dict(COSTS), weight=1.0),
    ]


def _violation_rate(res, uo, iso: dict, slo: dict) -> dict:
    per: dict[str, list[float]] = {}
    for tenant, a, f, alive in _unit_metrics(res, uo):
        if alive and tenant in slo:
            per.setdefault(tenant, []).append((f - a) / iso[tenant])
    return {t: sum(1 for s in v if s > slo[t]) / len(v)
            for t, v in per.items() if v}


def slo_debt_part(quick: bool) -> tuple[dict, list]:
    topos = make_table2_topologies()
    names = (["2D-SW_SW", "3D-SW_SW_SW_homo"] if quick else
             ["2D-SW_SW", "3D-SW_SW_SW_homo", "4D-Ring_FC_Ring_SW"])
    results = []
    wins = 0
    for tn in names:
        topo = topos[tn]
        # Per-topology saturation + isolated unit latency.
        g1 = serving_traffic(name="web", arrival_times=[0.0] * 8, **COSTS)
        res1, _ = simulate_traffic(topo, g1, engine="indexed")
        sat = 8 / res1.makespan
        lone = serving_traffic(name="web", arrival_times=[0.0], **COSTS)
        res_lone, _ = simulate_traffic(topo, lone, engine="indexed")
        iso_unit = res_lone.makespan
        tenants = _slo_tenants(sat)
        g = fleet_traffic(tenants, horizon_s=(12 if quick else 24) / sat)
        uo, _up = unit_of_group(g)
        specs = fleet_tenant_specs(tenants)
        # The arbiter's internal slowdown ledger runs on per-group
        # latencies; feed it a per-group-scale isolated latency while the
        # study scores on per-unit slowdowns.
        iso_group = {"web": iso_unit / (2 + COSTS["gen_tokens"])}
        iso = {"web": iso_unit}
        slo = {"web": 2.5}
        rates = {}
        for label, arb in (
                ("slo-aware", FabricArbiter("slo-aware", specs,
                                            isolated_latency=iso_group)),
                ("slo-debt", SloDebtArbiter(specs,
                                            isolated_latency=iso_group,
                                            horizon_s=6.0 / sat,
                                            gain=2.0, alpha=0.4))):
            res, _ = simulate_traffic(topo, g, engine="indexed",
                                      arbiter=arb, check_invariants=True)
            vr = _violation_rate(res, uo, iso, slo)
            rates[label] = max(vr.values()) if vr else 0.0
        win = rates["slo-debt"] <= rates["slo-aware"] + 1e-12
        wins += win
        results.append({"topology": tn, "sat_rate_rps": sat,
                        "violation_rate": rates, "debt_no_worse": win})
    need = 2 if len(names) >= 3 else len(names) - 1
    if wins < need:
        raise AssertionError(
            f"slo-debt gate failed: no worse on {wins}/{len(names)} "
            f"topologies (need >= {need}): {results}")
    out = {"topologies": results, "wins": wins, "needed": need}
    rows = [row("fleet/slo_debt", 0.0,
                f"debt no worse on {wins}/{len(names)} topologies "
                "(worst-tenant violation rate)")]
    return out, rows


def run(quick: bool = False):
    calib, rows = calibrate_part(quick)
    knee, knee_rows = knee_part(quick, calib)
    diff, diff_rows = differential_part(quick, calib)
    slo, slo_rows = slo_debt_part(quick)
    rows += knee_rows + diff_rows + slo_rows
    report = {
        "quick": quick,
        "calibrate": calib,
        "knee": knee,
        "differential": diff,
        "slo_debt": slo,
        "checks": {
            "knee_gates_passed": True,
            "overload_engines_identical": True,
            "slo_debt_gate_passed": True,
        },
    }
    OUT_JSON.write_text(json.dumps(report, indent=2) + "\n")
    rows.append(row("fleet/json", 0.0, f"json={OUT_JSON.name}"))
    return rows


def main() -> None:
    quick = "--quick" in sys.argv[1:]
    from benchmarks.common import print_rows

    print("name,us_per_call,derived")
    print_rows(run(quick=quick))


if __name__ == "__main__":
    main()
