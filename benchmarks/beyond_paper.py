"""Beyond-paper scheduler study: independent-AG greedy, 1-step lookahead,
water-filling unequal chunks — vs Themis greedy, at low chunk counts where
the greedy's quantization hurts most (Fig. 10 regime)."""
import statistics

from benchmarks.common import row, timed
from repro.core.simulator import simulate_scheduled
from repro.topology import make_table2_topologies

CPCS = [4, 8, 16, 64]


def run():
    rows = []
    topos = make_table2_topologies()
    agg = {}
    for name in ("3D-SW_SW_SW_hetero", "4D-Ring_FC_Ring_SW",
                 "3D-SW_SW_SW_homo"):
        topo = topos[name]
        for cpc in CPCS:
            res = {}
            us_tot = 0.0
            for policy, wf in (("themis", False), ("themis_indep_ag", False),
                               ("lookahead", False), ("themis_guarded", False),
                               ("themis", True)):
                key = "waterfill" if wf else policy
                (r, _), us = timed(
                    simulate_scheduled, topo, "AR", 100e6, policy=policy,
                    chunks_per_collective=cpc, intra="SCF", water_filling=wf)
                res[key] = r.avg_bw_utilization(topo)
                us_tot += us
                agg.setdefault(key, []).append(res[key])
            rows.append(row(
                f"beyond/{name}/cpc{cpc}", us_tot / 5,
                " ".join(f"{k}={v*100:.1f}%" for k, v in res.items())))
    rows.append(row(
        "beyond/SUMMARY", 0.0,
        " ".join(f"{k}_avg={statistics.mean(v)*100:.1f}%"
                 for k, v in agg.items())))
    return rows
