"""Verification study: SMT prover verdicts + runtime sanitizer overhead.

Three parts, emitted into ``BENCH_verify.json``:

  * **prover** — runs :func:`repro.verify.verify_suite` over the default
    instance grid: every (instance, property) decision, which backend
    decided it (witness evaluation always; z3 proof when installed), and
    every refutation's dual-engine counterexample replay.  The expected
    verdict pattern is asserted here, so CI fails if a verdict flips:
    conservation/ordering/starvation theorems proved everywhere,
    bounded_slowdown proved under the clamped weighted-fair arbiter and
    refuted for the stale-clock and fifo instances.
  * **sanitizer** — times a pinned multi-tenant preemption stream on both
    engines with ``check_invariants`` off and on.  Off must cost nothing
    measurable (it is one predicate per event); the JSON records both
    ratios so a regression shows up in the artifact trail.
  * **environment** — whether z3 was importable (the native witness
    backend is authoritative either way).

Run standalone (``python -m benchmarks.verify_study [--quick]``) or via
``python -m benchmarks.run verify``.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from benchmarks.common import row, timed_best
from repro.core.requests import CollectiveRequest
from repro.core.simulator import simulate_requests
from repro.tenancy import FabricArbiter, TenantSpec
from repro.topology import make_table2_topologies
from repro.verify import verify_suite, z3_available

MB = 1e6
OUT_JSON = Path(__file__).resolve().parents[1] / "BENCH_verify.json"

# (instance, property) -> expected verdict; anything else in the report
# must be proved.  A flip here is a semantics change, not noise.
EXPECTED_REFUTED = {
    ("wf-rearrival-stale", "bounded_slowdown"),
    ("fifo-mixed", "bounded_slowdown"),
}


def prover_part(quick: bool) -> tuple[dict, list]:
    rep = verify_suite(quick=quick)
    rows = []
    for v in rep["verdicts"]:
        key = (v["instance"], v["property"])
        want = "refuted" if key in EXPECTED_REFUTED else "proved"
        if v["status"] != want:
            raise AssertionError(
                f"verdict flip: {key} is {v['status']}, expected {want}")
        if v["status"] == "refuted":
            if not v["replays"]:
                raise AssertionError(f"refutation {key} has no replay")
            for r in v["replays"]:
                if not r["engines_bit_identical"]:
                    raise AssertionError(f"replay of {key} diverged")
    refuted = [v for v in rep["verdicts"] if v["status"] == "refuted"]
    rows.append(row(
        "verify/prover", 0.0,
        f"decided={rep['n_decided']} proved={rep['n_proved']} "
        f"refuted={rep['n_refuted']} "
        f"properties={len(rep['properties_decided'])} "
        f"replays={sum(len(v['replays']) for v in refuted)}"))
    return rep, rows


def sanitizer_part(quick: bool) -> tuple[dict, list]:
    n_req = 24 if quick else 64
    topo = make_table2_topologies()["2D-SW_SW"]
    specs = [TenantSpec("heavy", weight=1.0),
             TenantSpec("light", weight=4.0, priority=5)]
    reqs = [CollectiveRequest(
        "AR", (200.0 if i % 4 == 0 else 4.0) * MB,
        issue_time=i * 2e-4, tenant="heavy" if i % 4 == 0 else "light")
        for i in range(n_req)]

    def run_once(eng: str, chk: bool):
        arb = FabricArbiter("weighted-fair", specs, quantum_chunks=8,
                            preemption=True)
        return simulate_requests(topo, reqs, chunks_per_collective=16,
                                 arbiter=arb, engine=eng,
                                 check_invariants=chk)

    out: dict = {}
    rows = []
    repeat = 3 if quick else 5
    for eng in ("indexed", "reference"):
        (res_off, _), t_off = timed_best(run_once, eng, False,
                                         repeat=repeat)
        (res_on, _), t_on = timed_best(run_once, eng, True, repeat=repeat)
        if res_off.diff_fields(res_on):
            raise AssertionError(
                f"check_invariants changed {eng} results: "
                f"{res_off.diff_fields(res_on)}")
        out[eng] = {"off_s": t_off, "on_s": t_on,
                    "on_over_off": t_on / t_off}
        rows.append(row(
            f"verify/sanitizer/{eng}", t_off * 1e6,
            f"on/off={t_on / t_off:.2f}x results_identical=True"))
    return out, rows


def run(quick: bool = False):
    prover, rows = prover_part(quick)
    sanitizer, san_rows = sanitizer_part(quick)
    rows += san_rows
    report = {
        "quick": quick,
        "z3_available": z3_available(),
        "prover": prover,
        "sanitizer": sanitizer,
        "checks": {
            "verdict_pattern_ok": True,
            "replays_bit_identical": True,
            "sanitizer_results_identical": True,
        },
    }
    OUT_JSON.write_text(json.dumps(report, indent=2) + "\n")
    rows.append(row("verify/json", 0.0, f"json={OUT_JSON.name}"))
    return rows


def main() -> None:
    quick = "--quick" in sys.argv[1:]
    from benchmarks.common import print_rows

    print("name,us_per_call,derived")
    print_rows(run(quick=quick))


if __name__ == "__main__":
    main()
