"""Multi-tenant fabric study: tenants x arbiter policies x topologies.

Three experiments on shared Table-2 fabrics:

  * **fairness** — an asymmetric pair (a heavy batch tenant issuing few
    huge All-Reduces vs. a light latency-sensitive tenant issuing many
    small ones) swept over the inter-tenant arbiter policies.  Reports
    per-tenant slowdown vs. running alone, Jain's fairness index over
    slowdowns, SLO violations, and preemption counts — `weighted-fair`
    should beat `fifo` on Jain everywhere.
  * **workloads** — the same sweep with real training tenants
    (ResNet-152 bucket stream vs. GNMT) built from ``TenantJob``.
  * **tracker ablation** — three staggered tenants under the
    `weighted-fair` arbiter, scheduled by the cross-tenant Themis with one
    *shared* fabric-wide Dim Load Tracker vs. blind *per-tenant* trackers.
  * **preemption cost** — the fairness scenario under `weighted-fair` with
    a swept ``preempt_penalty_s`` (re-arm latency charged to chunks a
    preemption requeues).  Free splits (0.0) are the upper bound on the
    light tenant's benefit; growing penalties show when chunk-granularity
    preemption stops paying for itself.

Emits ``BENCH_tenancy.json`` at the repo root (machine-readable perf
trajectory) plus the usual CSV rows.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import row, timed
from repro.core.workloads import make_gnmt, make_resnet152
from repro.tenancy import (
    FabricArbiter,
    TenantJob,
    TenantSpec,
    fairness_index,
    isolated_latencies,
    mean_slowdown,
    simulate_fabric,
    slo_violations,
    synthetic_requests,
    tenant_reports,
)
from repro.topology import make_table2_topologies

MB = 1e6
TOPO_NAMES = ("2D-SW_SW", "3D-SW_SW_SW_homo", "3D-SW_SW_SW_hetero")
POLICIES = ("fifo", "strict-priority", "weighted-fair", "slo-aware")
CHUNKS = 16
OUT_JSON = Path(__file__).resolve().parents[1] / "BENCH_tenancy.json"


def _fairness_tenants():
    specs = [
        TenantSpec("batch", weight=1.0),
        TenantSpec("prod", weight=1.0, priority=1, slo_slowdown=1.5),
    ]
    reqs = (synthetic_requests("batch", "AR", 400 * MB, 3)
            + synthetic_requests("prod", "AR", 10 * MB, 12,
                                 gap_s=0.0005, start_s=0.0002))
    return specs, reqs


def _workload_tenants():
    light = TenantJob(
        TenantSpec("resnet", weight=1.0, priority=1, slo_slowdown=2.0,
                   arrival_offset_s=0.005, iterations=2, n_buckets=8),
        make_resnet152())
    heavy = TenantJob(
        TenantSpec("gnmt", weight=1.0, iterations=2, n_buckets=2),
        make_gnmt())
    specs = [light.spec, heavy.spec]
    return specs, light.requests() + heavy.requests()


def _ablation_tenants(stagger: float = 0.001):
    specs = [TenantSpec(n) for n in ("a", "b", "c")]
    reqs = []
    for i, s in enumerate(specs):
        reqs += synthetic_requests(s.name, "AR", 200 * MB, 3,
                                   gap_s=3 * stagger, start_s=i * stagger)
    return specs, reqs


def _policy_cell(topo, reqs, specs, iso, policy):
    spec_map = {s.name: s for s in specs}
    iso_mean = {t: sum(v) / len(v) for t, v in iso.items()}
    arb = FabricArbiter(policy, specs, isolated_latency=iso_mean)
    (res, _), us = timed(simulate_fabric, topo, reqs, arbiter=arb,
                         chunks_per_collective=CHUNKS)
    reps = tenant_reports(res, reqs, iso, spec_map)
    return us, {
        "jain": fairness_index(reps),
        "mean_slowdown": mean_slowdown(reps),
        "makespan_ms": res.finish_time() * 1e3,
        "slo_violations": slo_violations(reps),
        "preemptions": arb.preempt_count,
        "tenants": {
            t: {"mean_slowdown": r.mean_slowdown,
                "finish_ms": r.finish_s * 1e3,
                "bw_share": r.bw_share,
                "slo_violated": r.slo_violated}
            for t, r in reps.items()
        },
    }


def _sweep(topo, scenario_fn):
    specs, reqs = scenario_fn()
    iso = isolated_latencies(topo, reqs, chunks_per_collective=CHUNKS)
    cells = {}
    us_tot = 0.0
    for policy in POLICIES:
        us, cell = _policy_cell(topo, reqs, specs, iso, policy)
        us_tot += us
        cells[policy] = cell
    return us_tot / len(POLICIES), cells, (specs, reqs, iso)


def _ablation(topo):
    specs, reqs = _ablation_tenants()
    spec_map = {s.name: s for s in specs}
    iso = isolated_latencies(topo, reqs, chunks_per_collective=32)
    out = {}
    us_tot = 0.0
    for mode, shared in (("shared", True), ("per_tenant", False)):
        arb = FabricArbiter("weighted-fair", specs)
        (res, _), us = timed(simulate_fabric, topo, reqs, arbiter=arb,
                             shared_tracker=shared, chunks_per_collective=32)
        us_tot += us
        reps = tenant_reports(res, reqs, iso, spec_map)
        out[mode] = {"makespan_ms": res.finish_time() * 1e3,
                     "mean_slowdown": mean_slowdown(reps)}
    out["shared_wins"] = (
        out["shared"]["makespan_ms"] < out["per_tenant"]["makespan_ms"]
        or out["shared"]["mean_slowdown"] < out["per_tenant"]["mean_slowdown"])
    return us_tot / 2, out


PREEMPT_PENALTIES_S = (0.0, 50e-6, 200e-6, 1e-3)


def _preemption_cost(topo, specs, reqs, iso):
    """Penalty sweep on the fairness scenario (reuses its isolated refs)."""
    spec_map = {s.name: s for s in specs}
    out = {}
    us_tot = 0.0
    for penalty in PREEMPT_PENALTIES_S:
        arb = FabricArbiter("weighted-fair", specs,
                            preempt_penalty_s=penalty)
        (res, _), us = timed(simulate_fabric, topo, reqs, arbiter=arb,
                             chunks_per_collective=CHUNKS)
        us_tot += us
        reps = tenant_reports(res, reqs, iso, spec_map)
        out[f"{penalty * 1e6:.0f}us"] = {
            "makespan_ms": res.finish_time() * 1e3,
            "prod_slowdown": reps["prod"].mean_slowdown,
            "jain": fairness_index(reps),
            "preemptions": arb.preempt_count,
        }
    return us_tot / len(PREEMPT_PENALTIES_S), out


def run():
    topos = make_table2_topologies()
    rows = []
    report: dict = {"scenarios": {}, "checks": {}}
    wf_beats_fifo: list[str] = []
    shared_wins: list[str] = []
    for tname in TOPO_NAMES:
        topo = topos[tname]
        trep: dict = {}
        fairness_ctx = None
        for scen, fn in (("fairness", _fairness_tenants),
                         ("workloads", _workload_tenants)):
            us, cells, ctx = _sweep(topo, fn)
            if scen == "fairness":
                fairness_ctx = ctx
            trep[scen] = cells
            for policy, c in cells.items():
                rows.append(row(
                    f"tenancy/{tname}/{scen}/{policy}", us,
                    f"jain={c['jain']:.4f} mean_sd={c['mean_slowdown']:.3f} "
                    f"makespan={c['makespan_ms']:.3f}ms "
                    f"slo_viol={c['slo_violations']} "
                    f"preempts={c['preemptions']}"))
            if scen == "fairness" and (cells["weighted-fair"]["jain"]
                                       > cells["fifo"]["jain"]):
                wf_beats_fifo.append(tname)
        us, pc = _preemption_cost(topo, *fairness_ctx)
        trep["preemption_cost"] = pc
        free = pc["0us"]
        worst = pc[f"{PREEMPT_PENALTIES_S[-1] * 1e6:.0f}us"]
        rows.append(row(
            f"tenancy/{tname}/preemption_cost", us,
            f"free: prod_sd={free['prod_slowdown']:.3f} "
            f"preempts={free['preemptions']} | "
            f"{PREEMPT_PENALTIES_S[-1] * 1e6:.0f}us: "
            f"prod_sd={worst['prod_slowdown']:.3f} "
            f"preempts={worst['preemptions']}"))
        us, abl = _ablation(topo)
        trep["tracker_ablation"] = abl
        if abl["shared_wins"]:
            shared_wins.append(tname)
        rows.append(row(
            f"tenancy/{tname}/tracker_ablation", us,
            f"shared: makespan={abl['shared']['makespan_ms']:.3f}ms "
            f"mean_sd={abl['shared']['mean_slowdown']:.3f} | per-tenant: "
            f"makespan={abl['per_tenant']['makespan_ms']:.3f}ms "
            f"mean_sd={abl['per_tenant']['mean_slowdown']:.3f} | "
            f"shared_wins={abl['shared_wins']}"))
        report["scenarios"][tname] = trep
    report["checks"]["weighted_fair_beats_fifo_jain_on"] = wf_beats_fifo
    report["checks"]["shared_tracker_wins_on"] = shared_wins
    OUT_JSON.write_text(json.dumps(report, indent=2) + "\n")
    rows.append(row(
        "tenancy/checks", 0.0,
        f"weighted-fair>fifo jain on {len(wf_beats_fifo)}/{len(TOPO_NAMES)} "
        f"topologies {wf_beats_fifo}; shared tracker wins on "
        f"{len(shared_wins)}/{len(TOPO_NAMES)} {shared_wins}; "
        f"json={OUT_JSON.name}"))
    return rows
