"""Serving example: sharded prefill + batched autoregressive decode with a
KV cache (optionally int8-quantized), on 8 virtual devices.

    PYTHONPATH=src python examples/serve_decode.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import ParallelConfig, ShapeConfig, get_arch  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.train.serve import make_serve_fns  # noqa: E402

mesh = make_mesh((2, 4), ("data", "model"))
for kv_quant in (False, True):
    cfg = get_arch("llama3-8b", reduced=True).replace(kv_quant=kv_quant)
    api = build_model(cfg)
    shape = ShapeConfig("serve", 64, 4, "decode")
    jit_prefill, jit_decode, _ = make_serve_fns(
        api, mesh, ParallelConfig(data=2, model=4), shape)
    params = api.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)
    logits, caches = jit_prefill(params, {"tokens": prompt})
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    toks = [tok]
    t0 = time.time()
    for i in range(16):
        logits, caches = jit_decode(params, caches, tok,
                                    jnp.asarray(32 + i, jnp.int32))
        tok = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
        toks.append(tok)
    dt = time.time() - t0
    print(f"kv_quant={kv_quant}: decoded 16 tokens x batch 4 in {dt:.2f}s; "
          f"sample ids {[int(t[0]) for t in toks[:8]]}")
