"""Flight-recorder demo: trace a contended multi-tenant run, print the
windowed per-tenant bandwidth shares, and export a Chrome trace you can
open in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.

    PYTHONPATH=src python examples/trace_demo.py
"""
from repro.obs import BwTimeline, Tracer, enable_global, disable_global
from repro.tenancy import (
    FabricArbiter,
    TenantSpec,
    simulate_fabric,
    synthetic_requests,
)
from repro.topology import make_table2_topologies

MB = 1e6
topo = make_table2_topologies()["2D-SW_SW"]

# A heavy training tenant sharing the fabric with a latency-sensitive one.
specs = [TenantSpec("train", weight=1.0),
         TenantSpec("serve", weight=1.0, priority=1, slo_slowdown=1.5)]
reqs = (synthetic_requests("train", "AR", 200 * MB, 2)
        + synthetic_requests("serve", "AR", 8 * MB, 6,
                             gap_s=0.0004, start_s=0.0002))
arbiter = FabricArbiter("weighted-fair", specs,
                        isolated_latency={"serve": 0.001})

# Arm the flight recorder + the scheduler metrics registry for one run.
tracer = Tracer()
registry = enable_global()
res, _ = simulate_fabric(topo, reqs, arbiter=arbiter,
                         chunks_per_collective=8, tracer=tracer)
disable_global()

print(f"{topo.name}: makespan {res.makespan * 1e3:.2f} ms, "
      f"avg util {res.avg_bw_utilization(topo) * 100:.1f}%, "
      f"{len(tracer.preempts)} preemptions\n")

# Windowed per-tenant BW shares — the feedback signal a contention-aware
# scheduler would consume.
tl = BwTimeline.from_tracer(tracer)
win = res.makespan / 6
shares = tl.per_dim_shares(win)
for dim in range(topo.num_dims):
    print(f"dim{dim + 1} BW share per {win * 1e3:.2f} ms window:")
    for tenant in sorted(shares):
        cells = " ".join(f"{s * 100:5.1f}%" for s in shares[tenant][dim])
        print(f"  {tenant:6s} {cells}")
print()

print("scheduler metrics:")
for line in registry.report_rows():
    print(line)
last = registry.decisions[-1]
print(f"\nlast decision: {last.tenant} {last.collective} -> chunk order "
      f"{last.chunk_order} (cache {'hit' if last.cache_hit else 'miss'})")

out = "trace_demo.trace.json"
tracer.save(out)
print(f"\nwrote {out} — load it in https://ui.perfetto.dev")
