"""End-to-end Themis demo: ZeRO-2 data-parallel training where the gradient
reduce-scatter / parameter all-gather is chunked and scheduled by Themis
across a 3-axis device mesh — the paper's technique driving a real train
step.  Runs on CPU with 8 virtual devices.

    PYTHONPATH=src python examples/themis_zero2.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ParallelConfig, TrainConfig, get_arch  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.train.step import make_themis_train_step  # noqa: E402

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_arch("qwen2.5-3b", reduced=True)
api = build_model(cfg)

for policy in ("hier_baseline", "themis"):
    parallel = ParallelConfig(data=2, model=2, pods=2, dp_sync=policy,
                              chunks_per_collective=8)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=2, total_steps=30)
    step, init_state, orders = make_themis_train_step(api, mesh, parallel, tcfg)
    params, opt = init_state()
    print(f"\n=== dp_sync={policy} ===")
    uniq = {}
    for o in orders:
        uniq[o] = uniq.get(o, 0) + 1
    for o, n in uniq.items():
        print(f"  {n:2d} chunks take RS order {'->'.join(o)}")
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (8, 64)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (8, 64)), jnp.int32),
    }
    losses = []
    for i in range(20):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    print(f"  loss {losses[0]:.4f} -> {losses[-1]:.4f} over 20 steps "
          "(overfitting one batch)")
