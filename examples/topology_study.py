"""Network-design study (paper Sec. 6.3): sweep the dim2:dim1 BW ratio of a
2-level network and see where baseline scheduling wastes bandwidth, where
Themis recovers it, and where no scheduler can help (under-provisioned).

    PYTHONPATH=src python examples/topology_study.py
"""
from repro.core.insights import classify_pair
from repro.core.simulator import simulate_scheduled
from repro.topology.topology import NetworkDim, Topology, TopoKind

P1, P2 = 16, 8
BW1 = 800.0  # Gb/s aggregate on dim1

print(f"2-level network {P1}x{P2}, dim1 BW={BW1:.0f} Gb/s; sweeping dim2 BW\n")
print(f"{'dim2 BW':>9s} {'verdict':>18s} {'baseline':>9s} {'themis':>8s} "
      f"{'speedup':>8s}")
for bw2 in (12.5, 50, 100, 200, 400, 800, 1600):
    topo = Topology("study", (
        NetworkDim(P1, TopoKind.SWITCH, BW1, 1, 7e-7),
        NetworkDim(P2, TopoKind.SWITCH, bw2, 1, 1.7e-6),
    ))
    v = classify_pair(topo, 0, 1, tol=0.05)
    rb, _ = simulate_scheduled(topo, "AR", 5e8, policy="baseline", intra="FIFO")
    rt, _ = simulate_scheduled(topo, "AR", 5e8, policy="themis", intra="SCF")
    print(f"{bw2:7.1f}Gb {v.verdict:>18s} "
          f"{rb.avg_bw_utilization(topo)*100:8.1f}% "
          f"{rt.avg_bw_utilization(topo)*100:7.1f}% "
          f"{rb.makespan/rt.makespan:7.2f}x")
print("\n'just-enough' (ratio==1) is BW1 = P1 x BW2 = "
      f"{BW1/P1:.1f} Gb/s on dim2 — below it no scheduler can drive both "
      "dims (under-provisioned); above it Themis recovers what baseline "
      "strands (over-provisioned).")
