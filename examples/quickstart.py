"""Quickstart: schedule a collective with Themis, simulate it, and see the
paper's effect in 30 seconds on a laptop.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.latency_model import LatencyModel
from repro.core.simulator import simulate_scheduled
from repro.topology import make_table2_topologies

topo = make_table2_topologies()["3D-SW_SW_SW_homo"]
lm = LatencyModel(topo)
size = 1e9  # 1 GB All-Reduce

print(f"Topology {topo.name} ({topo.size_str()}, {topo.total_npus} NPUs), "
      f"1 GB All-Reduce, 64 chunks\n")
for policy, intra in (("baseline", "FIFO"), ("themis", "FIFO"),
                      ("themis", "SCF")):
    res, chunks = simulate_scheduled(topo, "AR", size, policy=policy,
                                     intra=intra)
    util = res.avg_bw_utilization(topo) * 100
    acts = " ".join(f"dim{k+1}={res.activity_rate(k)*100:4.0f}%"
                    for k in range(topo.num_dims))
    print(f"{policy:9s}+{intra:4s}: {res.makespan*1e3:7.2f} ms "
          f"(util {util:5.1f}%)  activity: {acts}")
print(f"{'ideal':14s}: {lm.ideal_time('AR', size)*1e3:7.2f} ms (util 100.0%)")

print("\nPer-chunk schedules Themis chose (first 6 chunks):")
_, chunks = simulate_scheduled(topo, "AR", size, policy="themis")
for c in chunks[:6]:
    order = "->".join(f"dim{d+1}" for p, d in c.schedule[:topo.num_dims])
    print(f"  chunk {c.index}: RS {order} (AG reversed)")
