#!/usr/bin/env python
"""Engine-hygiene lint for the simulator hot paths.

Walks ``src/repro/core/`` and ``src/repro/tenancy/`` ASTs and rejects two
classes of constructs that have no business in deterministic, replayable
engine code:

  * **float equality** — ``==`` / ``!=`` where an operand is visibly a
    float: a float literal, a ``float(...)`` call, or arithmetic that
    produces one (any expression containing a division or a float
    literal).  Bit-equivalence between the engines is proved by comparing
    *accumulation order*, not by tolerant comparison — ad-hoc float
    equality in the engines is either a latent flake or a tolerance that
    hides accounting bugs (see ``repro.core.invariants``).
  * **wall-clock reads** — ``time.time()``, ``perf_counter()``,
    ``monotonic()``, ``datetime.now()`` and friends.  Simulated time is
    the only clock the engines may observe; a wall-clock read makes runs
    unreproducible and breaks the verify witness replay.
  * **unguarded tracer calls** — any call on a tracer-ish name (``tracer``
    or ``trc*``: the flight-recorder handle and its pre-bound hook
    aliases) that is not lexically inside an ``if``/conditional whose test
    mentions a tracer-ish name.  The observability contract is *zero
    overhead when disabled*: every hook invocation in an engine hot loop
    must sit behind an ``if trc is not None``-style branch, so the
    disabled path costs one predictable branch per event and nothing else.
  * **unguarded fault-machinery calls** — same contract for the
    fault-injection fabric: any call on a fault-ish name (``faults`` or
    ``flt*``: the compiled schedule handle and the engines' fault
    closures) must sit behind an ``if``/conditional whose test mentions a
    fault-ish name (the ``if flt is not None`` pattern).  The fault-free
    path must stay byte-for-byte identical to pre-fault engines, so fault
    hooks may never run — or even be evaluated in an ``if``-test — at an
    unguarded level.
  * **unguarded admission calls** — same contract for the serving-fleet
    admission controller: any call on an admission-ish name
    (``admission`` or ``adm*``: the controller handle and the engines'
    admission closures) must sit behind an ``if``/conditional whose test
    mentions an admission-ish name (the ``if adm is not None`` pattern).
    The no-admission path is the production default; shedding logic may
    cost it nothing but the guard branch.
  * **scalar mutation inside vector zones** — sections bracketed by
    ``# lint: vector-zone-begin`` / ``# lint: vector-zone-end`` (the
    compiled engine's fused-numpy precompute and bulk-materialization
    blocks) promise *no per-event Python work*: every heapq call
    (``heappush``/``heappop``/...) and every mutating container-method
    call (``.append``/``.extend``/``.insert``/``.pop``/``.remove``/
    ``.popleft``/``.appendleft``/``.clear``) inside a zone is rejected.
    That is what keeps the compiled engine's O(n) sections actually
    vectorized — a stray ``events.append`` in a cohort loop silently
    degrades 10M-op runs back to interpreter speed.  Bounded per-run
    accumulations (e.g. per-size-class bookkeeping capped at 64 slots)
    are deliberate and carry ``# lint: allow``.  Unbalanced zone markers
    are themselves violations.

A line ending in a ``# lint: allow`` comment is exempt (used where the
construct is deliberate and documented, e.g. the exact-compare in the SMT
evaluator's mirror in invariants).

Usage: ``python tools/lint_engine.py [paths...]`` — defaults to the two
engine trees; exits 1 and prints ``file:line: message`` per violation.
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
DEFAULT_PATHS = ("src/repro/core", "src/repro/tenancy")

WALL_CLOCK_ATTRS = {
    "time": {"time", "time_ns", "perf_counter", "perf_counter_ns",
             "monotonic", "monotonic_ns", "process_time", "clock"},
    "datetime": {"now", "utcnow", "today"},
}
WALL_CLOCK_NAMES = (WALL_CLOCK_ATTRS["time"]
                    | WALL_CLOCK_ATTRS["datetime"]) - {"time"}

HEAPQ_FNS = {"heappush", "heappop", "heapify", "heappushpop", "heapreplace",
             "merge", "nlargest", "nsmallest"}
MUTATOR_METHODS = {"append", "extend", "insert", "pop", "remove", "clear",
                   "popleft", "appendleft", "extendleft"}

ZONE_BEGIN = "lint: vector-zone-begin"
ZONE_END = "lint: vector-zone-end"


def _vector_zones(lines: list[str]) -> tuple[list[tuple[int, int]],
                                             list[tuple[int, str]]]:
    """1-based (begin, end) line ranges of vector zones, plus marker
    errors (unmatched begin/end) as (lineno, message) pairs."""
    zones: list[tuple[int, int]] = []
    errors: list[tuple[int, str]] = []
    open_at: int | None = None
    for i, line in enumerate(lines, start=1):
        if ZONE_BEGIN in line:
            if open_at is not None:
                errors.append((i, "nested vector-zone-begin "
                               f"(zone opened at line {open_at} not closed)"))
            open_at = i
        elif ZONE_END in line:
            if open_at is None:
                errors.append((i, "vector-zone-end without a matching begin"))
            else:
                zones.append((open_at, i))
                open_at = None
    if open_at is not None:
        errors.append((open_at, "vector-zone-begin never closed"))
    return zones, errors


def _is_floatish(node: ast.expr) -> bool:
    """Is this expression visibly float-valued?  (Conservative: names and
    attribute loads are opaque — only literals, ``float()`` casts, and
    arithmetic that contains a division or float literal count.)"""
    if isinstance(node, ast.Constant):
        return isinstance(node.value, float)
    if isinstance(node, ast.Call):
        return isinstance(node.func, ast.Name) and node.func.id == "float"
    if isinstance(node, ast.BinOp):
        if isinstance(node.op, ast.Div):
            return True
        return _is_floatish(node.left) or _is_floatish(node.right)
    if isinstance(node, ast.UnaryOp):
        return _is_floatish(node.operand)
    return False


def _allowed(line: str) -> bool:
    return "lint: allow" in line


def _is_tracerish(name: str) -> bool:
    return name == "tracer" or name.startswith("trc")


def _is_faultish(name: str) -> bool:
    return name == "faults" or name.startswith("flt")


def _is_admissionish(name: str) -> bool:
    return name == "admission" or name.startswith("adm")


def _call_base(node: ast.expr, pred) -> str | None:
    """The matching base name of a call target, if any: ``trc_enq(...)``,
    ``trc.service_start(...)``, ``tracer.enq_dims.append(...)`` -> name."""
    while isinstance(node, ast.Attribute):
        node = node.value
    if isinstance(node, ast.Name) and pred(node.id):
        return node.id
    return None


def _test_mentions(test: ast.expr, pred) -> bool:
    return any(isinstance(n, ast.Name) and pred(n.id)
               for n in ast.walk(test))


def lint_file(path: Path) -> list[str]:
    src = path.read_text()
    lines = src.splitlines()
    try:
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as e:  # pragma: no cover - the test suite would fail first
        return [f"{path}:{e.lineno}: syntax error: {e.msg}"]
    try:
        rel = path.relative_to(REPO)
    except ValueError:  # outside the repo (e.g. a test's tmp file)
        rel = path
    out: list[str] = []

    def report(node: ast.AST, msg: str) -> None:
        line = lines[node.lineno - 1] if node.lineno <= len(lines) else ""
        if not _allowed(line):
            out.append(f"{rel}:{node.lineno}: {msg}")

    def check_guards(node: ast.AST, trc_guarded: bool,
                     flt_guarded: bool, adm_guarded: bool) -> None:
        """Reject tracer-hook / fault-machinery / admission calls outside
        a matching conditional branch (see module docstring: the
        zero-overhead-when-disabled contract, held separately per
        subsystem)."""
        if isinstance(node, (ast.If, ast.IfExp)):
            inner_trc = trc_guarded or _test_mentions(node.test, _is_tracerish)
            inner_flt = flt_guarded or _test_mentions(node.test, _is_faultish)
            inner_adm = adm_guarded or _test_mentions(node.test,
                                                      _is_admissionish)
            check_guards(node.test, trc_guarded, flt_guarded, adm_guarded)
            body = node.body if isinstance(node.body, list) else [node.body]
            orelse = (node.orelse if isinstance(node.orelse, list)
                      else [node.orelse] if node.orelse is not None else [])
            for child in body + orelse:
                check_guards(child, inner_trc, inner_flt, inner_adm)
            return
        if isinstance(node, ast.Call):
            base = _call_base(node.func, _is_tracerish)
            if base is not None and not trc_guarded:
                report(node, f"unguarded tracer call on {base!r} "
                       "(hot-loop hooks must sit behind an "
                       "'if <tracer> is not None' branch)")
            base = _call_base(node.func, _is_faultish)
            if base is not None and not flt_guarded:
                report(node, f"unguarded fault-machinery call on {base!r} "
                       "(fault hooks must sit behind an "
                       "'if <faults> is not None' branch)")
            base = _call_base(node.func, _is_admissionish)
            if base is not None and not adm_guarded:
                report(node, f"unguarded admission call on {base!r} "
                       "(admission hooks must sit behind an "
                       "'if <admission> is not None' branch)")
        for child in ast.iter_child_nodes(node):
            check_guards(child, trc_guarded, flt_guarded, adm_guarded)

    check_guards(tree, False, False, False)

    zones, zone_errors = _vector_zones(lines)
    for lineno, msg in zone_errors:
        out.append(f"{rel}:{lineno}: {msg}")

    def _in_zone(lineno: int) -> bool:
        return any(b <= lineno <= e for b, e in zones)

    if zones:
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call) and _in_zone(node.lineno)):
                continue
            f = node.func
            if isinstance(f, ast.Name) and f.id in HEAPQ_FNS:
                report(node, f"heapq call {f.id}() inside a vector zone "
                       "(fused numpy only; hoist event-queue work out of "
                       "the zone)")
            elif isinstance(f, ast.Attribute):
                if (isinstance(f.value, ast.Name) and f.value.id == "heapq"
                        and f.attr in HEAPQ_FNS):
                    report(node, f"heapq call heapq.{f.attr}() inside a "
                           "vector zone (fused numpy only; hoist event-"
                           "queue work out of the zone)")
                elif f.attr in MUTATOR_METHODS:
                    report(node, f"per-event container mutation .{f.attr}() "
                           "inside a vector zone (replace with a fused "
                           "numpy op or a bulk splice, or annotate a "
                           "bounded per-run accumulation with "
                           "'# lint: allow')")

    for node in ast.walk(tree):
        if isinstance(node, ast.Compare):
            operands = [node.left, *node.comparators]
            for op in node.ops:
                if isinstance(op, (ast.Eq, ast.NotEq)) and any(
                        _is_floatish(o) for o in operands):
                    report(node, "float equality comparison "
                           "(use an ordered check or an explicit tolerance)")
                    break
        elif isinstance(node, ast.Call):
            f = node.func
            if (isinstance(f, ast.Attribute)
                    and isinstance(f.value, ast.Name)
                    and f.attr in WALL_CLOCK_ATTRS.get(f.value.id, ())):
                report(node, f"wall-clock read {f.value.id}.{f.attr}() "
                       "(engines may only observe simulated time)")
            elif isinstance(f, ast.Name) and f.id in WALL_CLOCK_NAMES:
                report(node, f"wall-clock read {f.id}() "
                       "(engines may only observe simulated time)")
        elif isinstance(node, ast.ImportFrom):
            if node.module in ("time", "datetime"):
                bad = [a.name for a in node.names
                       if a.name in WALL_CLOCK_ATTRS[node.module]]
                if bad:
                    report(node, f"imports wall-clock {bad} from "
                           f"{node.module} (engines may only observe "
                           "simulated time)")
    return out


def main(argv: list[str]) -> int:
    paths = argv or [str(REPO / p) for p in DEFAULT_PATHS]
    violations: list[str] = []
    n_files = 0
    for p in paths:
        root = Path(p)
        files = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        for f in files:
            n_files += 1
            violations.extend(lint_file(f))
    for v in violations:
        print(v)
    print(f"lint_engine: {n_files} files, {len(violations)} violation(s)",
          file=sys.stderr)
    return 1 if violations else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
